"""Architecture zoo: per-arch smoke tests (reduced configs, one forward +
one train step, shape/NaN assertions) and prefill+decode == full-forward
equality (exact for deterministic paths; tolerance for capacity-MoE whose
token dropping is batch-size dependent by construction)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SMOKES
from repro.models import build, layers as L, transformer as T
from repro.train import AdamWConfig, make_train_step
from repro.train import optimizer as O

RNG = np.random.default_rng(0)
ARCH_NAMES = list(SMOKES)


def _batch(cfg, b=2, s=16):
    def toks(n, t):
        return jnp.asarray(RNG.integers(0, cfg.vocab_size, (n, t)), jnp.int32)
    if cfg.family == "encdec":
        return {"frames": jnp.asarray(
                    RNG.standard_normal((b, s, cfg.d_model)), jnp.float32),
                "tokens": toks(b, s), "labels": toks(b, s)}
    if cfg.family == "vlm":
        return {"patches": jnp.asarray(
                    RNG.standard_normal((b, cfg.num_patches, cfg.d_model)),
                    jnp.float32),
                "tokens": toks(b, s), "labels": toks(b, s)}
    return {"tokens": toks(b, s), "labels": toks(b, s)}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = SMOKES[name]
    api = build(cfg, tp=1)
    params = api.init_params(0)
    batch = _batch(cfg)
    loss, metrics = jax.jit(api.train_loss)(params, batch)
    assert np.isfinite(float(loss)), name
    step = make_train_step(api, AdamWConfig(lr=1e-3, warmup_steps=1))
    opt = O.init_state(params)
    p2, o2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if SMOKES[n].family != "encdec"])
def test_prefill_decode_matches_full(name):
    cfg = SMOKES[name]
    api = build(cfg, tp=1)
    params = api.init_params(0)
    b, t_prompt, t_total, cache_seq = 2, 12, 17, 32
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t_total)),
                       jnp.int32)
    if cfg.family == "vlm":
        patches = jnp.asarray(
            RNG.standard_normal((b, cfg.num_patches, cfg.d_model)),
            jnp.float32)
        xt = L.embed_apply(params["embed"], toks, cfg)
        x = jnp.concatenate([patches.astype(xt.dtype), xt], axis=1)
        x, _, _ = T.decoder_forward(params, cfg, x)
        ref = L.logits_apply(params["embed"], x[:, cfg.num_patches:], cfg)
        caches = L.init_tree(api.cache_defs(b, cache_seq + cfg.num_patches))
        lg, caches = api.prefill(
            params, {"patches": patches, "tokens": toks[:, :t_prompt]},
            caches)
        base = cfg.num_patches + t_prompt
    else:
        x = L.embed_apply(params["embed"], toks, cfg)
        x, _, _ = T.decoder_forward(params, cfg, x)
        ref = L.logits_apply(params["embed"], x, cfg)
        caches = L.init_tree(api.cache_defs(b, cache_seq))
        lg, caches = api.prefill(params, {"tokens": toks[:, :t_prompt]},
                                 caches)
        base = t_prompt
    tol = 5e-2 if cfg.moe is not None else 1e-4   # capacity-MoE drop noise
    np.testing.assert_allclose(lg[:, 0], ref[:, t_prompt - 1],
                               rtol=tol, atol=tol)
    lengths = jnp.full((b,), base, jnp.int32)
    for i in range(t_prompt, t_total):
        lg, caches = api.decode(params,
                                {"tokens": toks[:, i:i + 1],
                                 "lengths": lengths}, caches)
        np.testing.assert_allclose(lg[:, 0], ref[:, i], rtol=tol, atol=tol)
        lengths = lengths + 1


def test_encdec_prefill_decode():
    cfg = SMOKES["seamless-m4t-large-v2"]
    api = build(cfg, tp=1)
    params = api.init_params(0)
    b, s, t_total, t_prompt = 2, 10, 15, 9
    frames = jnp.asarray(RNG.standard_normal((b, s, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t_total)),
                       jnp.int32)
    # reference: full decoder forward (teacher forcing)
    from repro.models import encdec as E
    enc = E.encode(params, cfg, frames)
    x = L.embed_apply(params["embed"], toks, cfg)
    x, _ = E.decode_stack(params, cfg, x, enc)
    ref = L.logits_apply(params["embed"], x, cfg)

    caches = L.init_tree(api.cache_defs(b, 32))
    lg, caches, enc_out = api.prefill(
        params, {"frames": frames, "tokens": toks[:, :t_prompt]}, caches)
    np.testing.assert_allclose(lg[:, 0], ref[:, t_prompt - 1],
                               rtol=1e-4, atol=1e-4)
    lengths = jnp.full((b,), t_prompt, jnp.int32)
    for i in range(t_prompt, t_total):
        lg, caches = api.decode(
            params, {"tokens": toks[:, i:i + 1], "lengths": lengths,
                     "enc_out": enc_out}, caches)
        np.testing.assert_allclose(lg[:, 0], ref[:, i], rtol=1e-4, atol=1e-4)
        lengths = lengths + 1


def test_local_ring_cache_past_window():
    """Decode far past the sliding window: ring reuse must stay exact."""
    cfg = SMOKES["gemma3-12b"]            # window 8
    api = build(cfg, tp=1)
    params = api.init_params(0)
    b, t_total, t_prompt = 1, 30, 4
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t_total)),
                       jnp.int32)
    x = L.embed_apply(params["embed"], toks, cfg)
    x, _, _ = T.decoder_forward(params, cfg, x)
    ref = L.logits_apply(params["embed"], x, cfg)
    caches = L.init_tree(api.cache_defs(b, 64))
    lg, caches = api.prefill(params, {"tokens": toks[:, :t_prompt]}, caches)
    lengths = jnp.full((b,), t_prompt, jnp.int32)
    for i in range(t_prompt, t_total):
        lg, caches = api.decode(params, {"tokens": toks[:, i:i + 1],
                                         "lengths": lengths}, caches)
        np.testing.assert_allclose(lg[:, 0], ref[:, i], rtol=1e-4, atol=1e-4)
        lengths = lengths + 1


def test_tiny_overfit():
    """Training substrate sanity: loss decreases on a repeated batch."""
    cfg = SMOKES["llama3.2-3b"]
    api = build(cfg, tp=1)
    params = api.init_params(0)
    opt = O.init_state(params)
    step = jax.jit(make_train_step(api, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                    decay_steps=100)))
    batch = _batch(cfg, b=2, s=16)
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
