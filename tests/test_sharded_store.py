"""ShardedGraphStore: N-shard bit-equality with the single-device store
(sampling, embeddings, end-to-end inference), cross-shard mutable-op
routing, per-shard stats telemetry, and the bounded device event ring."""
import numpy as np
import pytest

from repro.core.service import HolisticGNNService, make_service_dfg
from repro.core import gnn
from repro.serve import ServingRuntime
from repro.store import (BlockDevice, GraphStore, ShardedGraphStore,
                         partition_csr, preprocess_edges, sample_batch,
                         sample_batch_ref)
from repro.store.blockdev import EVENTS_CAP


def _graph(n=400, e=3000, feat=24, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _pair(n_shards, *, h_threshold=16, n=400, e=3000, feat=24):
    """(single-device store, N-shard store) over the same ingested graph."""
    edges, emb = _graph(n, e, feat)
    single = GraphStore(BlockDevice(), h_threshold=h_threshold)
    single.update_graph(edges, emb)
    sharded = ShardedGraphStore(n_shards=n_shards, h_threshold=h_threshold)
    sharded.update_graph(edges, emb)
    return single, sharded, n


def _assert_batches_equal(a, b):
    np.testing.assert_array_equal(a.node_vids, b.node_vids)
    assert a.num_targets == b.num_targets
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.nbr, lb.nbr)
        np.testing.assert_array_equal(la.mask, lb.mask)
        assert la.num_dst == lb.num_dst
    np.testing.assert_array_equal(a.embeddings, b.embeddings)


# ------------------------------------------------------------ partitioning
def test_partition_csr_covers_and_masks():
    edges, _ = _graph()
    indptr, indices = preprocess_edges(edges)
    n = len(indptr) - 1
    total = 0
    for s in range(3):
        ip, ix = partition_csr(indptr, indices, 3, s)
        assert len(ip) == n + 1
        deg = np.diff(ip)
        owned = np.arange(n) % 3 == s
        assert (deg[~owned] == 0).all()
        np.testing.assert_array_equal(deg[owned], np.diff(indptr)[owned])
        total += int(deg.sum())
    assert total == len(indices)


# --------------------------------------------------------- read-side parity
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_neighbors_and_embeds_bit_identical(n_shards):
    single, sharded, n = _pair(n_shards)
    rng = np.random.default_rng(3)
    vids = rng.integers(0, n + 20, 80)           # includes unknown vids
    for a, b in zip(single.get_neighbors_batch(vids),
                    sharded.get_neighbors_batch(vids)):
        np.testing.assert_array_equal(a, b)
    known = vids[vids < n]
    np.testing.assert_array_equal(single.get_embeds(known),
                                  sharded.get_embeds(known))
    for v in known[:8]:
        np.testing.assert_array_equal(single.get_embed(int(v)),
                                      sharded.get_embed(int(v)))


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_sample_batch_bit_identical(n_shards):
    single, sharded, n = _pair(n_shards)
    targets = np.random.default_rng(5).integers(0, n, 12)
    got = sample_batch(sharded, targets, [5, 5],
                       rng=np.random.default_rng(9))
    want = sample_batch(single, targets, [5, 5],
                        rng=np.random.default_rng(9))
    oracle = sample_batch_ref(single, targets, [5, 5],
                              rng=np.random.default_rng(9))
    _assert_batches_equal(want, got)
    _assert_batches_equal(oracle, got)


@pytest.mark.parametrize("n_shards", [2, 3])
def test_sample_stays_identical_after_cross_shard_mutations(n_shards):
    single, sharded, n = _pair(n_shards)
    rng = np.random.default_rng(11)
    for _ in range(120):                         # mutate BOTH stores
        op = rng.integers(0, 4)
        a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
        if op == 0:
            single.add_edge(a, b), sharded.add_edge(a, b)
        elif op == 1:
            single.delete_edge(a, b), sharded.delete_edge(a, b)
        elif op == 2:
            v = n + int(rng.integers(0, 40))
            single.add_vertex(v), sharded.add_vertex(v)
        else:
            single.delete_vertex(a), sharded.delete_vertex(a)
    assert single.to_adjacency() == sharded.to_adjacency()
    # mutated pages exercise the L-locate general path on both sides
    targets = rng.integers(0, n, 10)
    got = sample_batch(sharded, targets, [4, 4],
                       rng=np.random.default_rng(1))
    want = sample_batch(single, targets, [4, 4],
                        rng=np.random.default_rng(1))
    _assert_batches_equal(want, got)


def test_update_embed_routes_to_owner_shard():
    _, sharded, n = _pair(3)
    writes0 = [d.stats.written_pages for d in sharded.devs]
    vid = 7                                      # owner = 7 % 3 = 1
    row = np.full(24, 2.5, dtype=np.float32)
    sharded.update_embed(vid, row)
    np.testing.assert_array_equal(sharded.get_embed(vid), row)
    writes = [d.stats.written_pages - w0
              for d, w0 in zip(sharded.devs, writes0)]
    assert writes[1] > 0 and writes[0] == 0 and writes[2] == 0


# ------------------------------------------------------- end-to-end serving
def _service_pair(n_shards, cache_pages=None):
    edges, emb = _graph(n=600, e=5000, feat=32)
    svcs = []
    for ns in (1, n_shards):
        svc = HolisticGNNService(h_threshold=16, pad_to=32,
                                 n_shards=ns, cache_pages=cache_pages)
        svc.store.update_graph(edges, emb)
        svcs.append(svc)
    return svcs[0], svcs[1]


@pytest.mark.parametrize("n_shards", [2, 4])
def test_run_and_run_batch_bit_identical(n_shards):
    ref, sharded = _service_pair(n_shards, cache_pages=512)
    assert isinstance(sharded.store, ShardedGraphStore)
    dfg = make_service_dfg("gcn", 2, [5, 5]).save()
    params = gnn.init_params("gcn", [32, 16, 8], seed=1)
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    out_a = ref.run(dfg, [3, 7, 11, 200], weights=weights, seed=42)
    out_b = sharded.run(dfg, [3, 7, 11, 200], weights=weights, seed=42)
    np.testing.assert_array_equal(out_a["Result"], out_b["Result"])
    reqs = [{"targets": [3, 7], "seed": 1},
            {"targets": [9, 20, 31], "seed": 2},
            {"targets": [100], "seed": 3}]
    for a, b in zip(ref.run_batch(dfg, reqs, weights=weights),
                    sharded.run_batch(dfg, reqs, weights=weights)):
        np.testing.assert_array_equal(a["Result"], b["Result"])


def test_stats_rpc_reports_per_shard_telemetry():
    _, sharded = _service_pair(3, cache_pages=600)
    vids = np.arange(12)
    sharded.store.get_embeds(vids)
    sharded.store.get_embeds(vids)              # second gather hits the cache
    st = sharded.stats()
    assert st["store"]["n_shards"] == 3
    assert len(st["shards"]) == 3
    agg_reads = sum(s["device"]["read_pages"] for s in st["shards"])
    assert st["device"]["read_pages"] == agg_reads > 0
    hit_rates = [s["embcache"]["hit_rate"] for s in st["shards"]]
    assert all(0.0 <= h <= 1.0 for h in hit_rates)
    # the aggregate embcache section sums the per-shard counters
    assert st["embcache"]["hits"] == sum(s["embcache"]["hits"]
                                         for s in st["shards"]) > 0


def test_mutable_ops_under_load_cross_shard():
    """Stepped runtime over a 3-shard service: scheduled run groups
    interleaved with mutations whose endpoints live on DIFFERENT shards;
    every output must stay bit-identical to a serial single-device twin
    receiving the same operation sequence (per-shard cache coherence)."""
    edges, emb = _graph(n=600, e=5000, feat=32)
    svc = HolisticGNNService(h_threshold=16, pad_to=32, n_shards=3,
                             cache_pages=600)
    svc.store.update_graph(edges, emb)
    ref = HolisticGNNService(h_threshold=16, pad_to=32)
    ref.store.update_graph(edges, emb)
    dfg = make_service_dfg("gcn", 2, [5, 5]).save()
    params = gnn.init_params("gcn", [32, 16, 8], seed=1)
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    rt = ServingRuntime(svc, n_queues=2, max_group=8)
    cl, mut = rt.client(), rt.client()
    rng = np.random.default_rng(7)
    seed_ctr = 0
    n = 600
    for round_ in range(5):
        cmds = []
        for _ in range(4):
            t = rng.integers(0, n, 6).tolist()
            cmds.append((t, seed_ctr,
                         cl.submit("run", dfg=dfg, batch=t, weights=weights,
                                   seed=seed_ctr)))
            seed_ctr += 1
        rt.pump()
        for t, s, cid in cmds:
            got = cl.result(cid)["Result"]
            want = ref.run(dfg, t, weights=weights, seed=s)["Result"]
            np.testing.assert_array_equal(want[:6], got[:6],
                                          err_msg=f"round {round_}")
        # cross-shard mutations: consecutive vids own to different shards
        a = int(rng.integers(0, n - 3))
        row = rng.standard_normal(32).astype(np.float32)
        mids = [mut.submit("add_edge", dst=a, src=a + 1),
                mut.submit("update_embed", vid=a + 2, embed=row),
                mut.submit("delete_vertex", vid=a + 3)]
        rt.pump()
        for mid in mids:
            mut.result(mid)
        ref.store.add_edge(a, a + 1)
        ref.store.update_embed(a + 2, row)
        ref.store.delete_vertex(a + 3)
    cache = svc.store.cache.stats
    assert cache.invalidations > 0 and cache.hits > 0


# ------------------------------------------------------- device event ring
def test_io_event_ring_is_bounded():
    dev = BlockDevice(64)
    page = np.zeros(1024, dtype=np.int32)
    for i in range(EVENTS_CAP + 500):
        dev.write_page(i % 64, page)
    assert len(dev.stats.events) == EVENTS_CAP
    assert dev.stats.written_pages == EVENTS_CAP + 500   # counters unbounded


def test_io_event_full_trace_opt_in():
    dev = BlockDevice(64, trace_events=True)
    page = np.zeros(1024, dtype=np.int32)
    for i in range(EVENTS_CAP + 500):
        dev.write_page(i % 64, page)
    assert len(dev.stats.events) == EVENTS_CAP + 500
