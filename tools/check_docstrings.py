#!/usr/bin/env python3
"""CI lint: every PUBLIC def/class on the operator-facing surface must
carry a docstring.

The operator guide (docs/operations.md) and architecture walk
(docs/architecture.md) point into these modules; an undocumented public
method there is a broken link in the docs.  Scope: the store/serve
surface named in docs/ — not the whole tree — so internal helpers stay
free to be terse (anything prefixed ``_`` is exempt, as are trivial
``__dunder__`` overrides other than ``__init__`` on public classes).

Pure stdlib (ast) — no pip dependency, runs anywhere CI does:

  python tools/check_docstrings.py [--verbose]
"""
from __future__ import annotations

import argparse
import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the public store/serve surface the docs point into
TARGETS = [
    "src/repro/store/sharded.py",
    "src/repro/store/endpoint.py",
    "src/repro/store/ingest.py",
    "src/repro/store/placement.py",
    "src/repro/serve/supervisor.py",
    "src/repro/core/service.py",
]


def _public_defs(tree: ast.Module):
    """Yield (qualname, node) for every public module-level def/class
    and every public method of a public class."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield node.name, node
        elif isinstance(node, ast.ClassDef):
            if node.name.startswith("_"):
                continue
            yield node.name, node
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    continue
                name = sub.name
                if name.startswith("_") and name != "__init__":
                    continue
                yield f"{node.name}.{name}", sub


def check_file(path: str) -> list[str]:
    with open(path) as fh:
        tree = ast.parse(fh.read(), filename=path)
    rel = os.path.relpath(path, REPO)
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{rel}:1: module docstring missing")
    for qual, node in _public_defs(tree):
        if ast.get_docstring(node) is None:
            # an undocumented __init__ is fine when the class docstring
            # covers construction
            if qual.endswith(".__init__"):
                continue
            kind = "class" if isinstance(node, ast.ClassDef) else "def"
            missing.append(f"{rel}:{node.lineno}: public {kind} "
                           f"`{qual}` has no docstring")
    return missing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--verbose", action="store_true",
                    help="also list the files that passed")
    args = ap.parse_args(argv)
    failures: list[str] = []
    for rel in TARGETS:
        path = os.path.join(REPO, rel)
        if not os.path.exists(path):
            failures.append(f"{rel}: target module missing")
            continue
        miss = check_file(path)
        if miss:
            failures.extend(miss)
        elif args.verbose:
            print(f"ok: {rel}")
    if failures:
        print(f"{len(failures)} undocumented public definition(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"docstring lint: {len(TARGETS)} modules clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
