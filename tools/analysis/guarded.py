"""Guarded-by pass: enforce ``# guarded-by: <lock-attr>`` annotations.

An annotation on an attribute's initialisation line in ``__init__``::

    self._pending = []          # guarded-by: _cond

declares that every later ``self._pending`` access *inside that class*
must happen while the named lock is held (a ``with self._cond:``
region, a ``# requires-lock: _cond`` helper, or a context-manager the
config knows holds it).  Rules:

  * **GB001** annotated attribute WRITTEN outside its lock
  * **GB002** annotated attribute READ outside its lock

Intentional lock-free snapshot reads either carry an inline
``# unguarded-ok: <reason>`` or an entry in the reviewed baseline
(``tools/analysis/guarded_baseline.txt``) — each with a one-line
justification.  The pass checks only annotated attributes accessed as
``self.<attr>`` within the declaring class, so it has no false
positives by construction; cross-class mutation must go through the
owning class's methods (which is the convention the annotations
document).
"""
from __future__ import annotations

import ast

from .core import (AnalysisConfig, Finding, FunctionWalker, GUARDED_TOKEN,
                   ModuleInfo, PackageIndex, UNGUARDED_TOKEN)


def collect_annotations(cfg: AnalysisConfig, mod: ModuleInfo
                        ) -> dict[tuple[str, str], str]:
    """(class, attr) -> lock name, from ``# guarded-by:`` comments on
    ``self.<attr> = ...`` lines."""
    out: dict[tuple[str, str], str] = {}
    for cls in mod.tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        for node in ast.walk(cls):
            if not isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                continue
            c = mod.comment(node.lineno)
            if GUARDED_TOKEN not in c:
                continue
            lock_attr = c.split(GUARDED_TOKEN, 1)[1].strip().split()[0]
            spec = cfg.resolve_attr(mod.modname, lock_attr)
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Attribute) \
                        and isinstance(tgt.value, ast.Name) \
                        and tgt.value.id == "self":
                    if spec is not None:
                        out[(cls.name, tgt.attr)] = spec.name
    return out


class _Checker(FunctionWalker):
    def __init__(self, cfg, index, fi, annotations, findings):
        super().__init__(cfg, index, fi)
        self.annotations = annotations
        self.findings = findings

    def on_access(self, attr, is_store, node):
        if self.fi.cls is None:
            return
        lock = self.annotations.get((self.fi.cls, attr))
        if lock is None or lock in self.held:
            return
        if self.fi.node.name == "__init__":
            return                      # construction precedes sharing
        line = node.lineno
        rule = "GB001" if is_store else "GB002"
        f = Finding(rule, self.fi.module.rel, line, self.fi.key,
                    f"self.{attr} ({'write' if is_store else 'read'}) "
                    f"outside its guard {lock}")
        if UNGUARDED_TOKEN in self.fi.module.comment(line):
            f.suppressed = True
        self.findings.append(f)


def run(cfg: AnalysisConfig, modules: list[ModuleInfo]) -> list[Finding]:
    index = PackageIndex(modules)
    findings: list[Finding] = []
    for mod in modules:
        annotations = collect_annotations(cfg, mod)
        if not annotations:
            continue
        for fi in index.functions.values():
            if fi.module is not mod or fi.cls is None:
                continue
            w = _Checker(cfg, index, fi, annotations, findings)
            try:
                w.run()
            except RecursionError:
                pass
    # deduplicate repeated hits on the same line/attr (e.g. `a = b = x`)
    seen: set[tuple] = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
