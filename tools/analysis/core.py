"""Shared machinery for the static passes: package loading, comment
maps, a class/method index with cross-module base resolution, lock
summaries propagated through the intra-package call graph, and the
held-lock-set function walker the lock-order and guarded-by passes
both drive.

Everything is parameterized by an ``AnalysisConfig`` so the fixture
corpus (`tools/analysis/fixtures/`) runs the identical engine against
a miniature registry.
"""
from __future__ import annotations

import ast
import io
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

# make `repro.concurrency` importable when running from tools/
import sys

_SRC = Path(__file__).resolve().parents[2] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro import concurrency as conc  # noqa: E402


# --------------------------------------------------------------- findings
@dataclass
class Finding:
    rule: str
    path: str           # repo-relative
    line: int
    func: str           # module.Class.method ('' for file-level)
    message: str
    suppressed: bool = False

    def key(self) -> str:
        return f"{self.rule}:{self.func}:{self.message}"

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}" \
               f" (in {self.func or '<module>'}){tag}"


# ----------------------------------------------------------------- config
# Methods whose call is an endpoint/RPC round trip: blocking, and the
# transport may take the queue-pair CV, the work signal, and the async
# client lock underneath.
RPC_BLOCKING_ATTRS = frozenset({
    "call", "call_submit", "call_result",
    "fetch", "fetch_submit", "fetch_result",
})
RPC_IMPLIED_LOCKS = ("queues.cv", "queues._work", "rpcclient._lock")

# Blocking by name: sleeps, joins, event waits.
BLOCKING_ATTRS = frozenset({"sleep", "join", "wait", "wait_for"})
BLOCKING_NAMES = frozenset({"sleep_us"})

SUPPRESS_TOKEN = "lock-order: ok"
UNGUARDED_TOKEN = "unguarded-ok:"
GUARDED_TOKEN = "guarded-by:"
REQUIRES_TOKEN = "requires-lock:"


@dataclass
class AnalysisConfig:
    """Registry + resolution tables one analysis run works against."""

    specs: tuple = conc.LOCK_ORDER
    sanctioned: dict = field(default_factory=lambda: dict(
        conc.SANCTIONED_EDGES))
    same_name_ok: dict = field(default_factory=lambda: dict(
        conc.SAME_NAME_OK))
    never_together: dict = field(default_factory=lambda: dict(
        conc.NEVER_TOGETHER))
    # context-manager methods that hold locks for their caller's body
    with_funcs: dict = field(default_factory=lambda: {
        "_write_gate": ("sharded._maintenance", "sharded._mutate"),
    })
    # `self.<attr>` object types, per module basename — lets the walker
    # resolve `self.store.method()` / `st = self.store; st.method()`
    # calls into the package class index
    attr_types: dict = field(default_factory=lambda: {
        ("endpoint", "store"): ("GraphStore",),
        ("ingest", "store"): ("ReplicatedGraphStore", "ShardedGraphStore",
                              "GraphStore"),
        ("supervisor", "store"): ("ReplicatedGraphStore",
                                  "ShardedGraphStore"),
        ("runtime", "scheduler"): ("BatchScheduler",),
        ("scheduler", "qos"): ("QoSTelemetry",),
    })

    def __post_init__(self):
        self.by_name = {s.name: s for s in self.specs}
        self.site_map = {}
        for s in self.specs:
            for mod, attr in s.sites:
                self.site_map[(mod, attr)] = s

    def resolve_attr(self, module: str, attr: str):
        return self.site_map.get((module, attr))


# ---------------------------------------------------------------- loading
@dataclass
class ModuleInfo:
    path: Path
    rel: str                 # repo-relative path string
    modname: str             # basename stem, e.g. "sharded"
    tree: ast.Module
    source: str
    comments: dict           # line -> comment text (sans '#')

    def comment(self, line: int) -> str:
        return self.comments.get(line, "")


def _comment_map(source: str) -> dict:
    out: dict[int, str] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:
        pass
    return out


def load_package(root: Path, repo_root: Path | None = None
                 ) -> list[ModuleInfo]:
    """Parse every ``.py`` under ``root`` (recursive, skipping caches)."""
    repo_root = repo_root or root
    mods = []
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" in p.parts:
            continue
        src = p.read_text()
        try:
            tree = ast.parse(src, filename=str(p))
        except SyntaxError as e:  # a fixture may be deliberately odd
            raise RuntimeError(f"{p}: unparseable: {e}") from e
        try:
            rel = str(p.relative_to(repo_root))
        except ValueError:
            rel = str(p)
        mods.append(ModuleInfo(p, rel, p.stem, tree, src,
                               _comment_map(src)))
    return mods


# ------------------------------------------------------------ class index
@dataclass
class FuncInfo:
    key: str                 # "modname.Class.method" / "modname.func"
    node: ast.AST            # FunctionDef
    module: ModuleInfo
    cls: str | None          # enclosing class name or None


class PackageIndex:
    """Classes, methods and module functions across the package, with
    base-class resolution by identifier name (cross-module)."""

    def __init__(self, modules: list[ModuleInfo]):
        self.modules = modules
        self.classes: dict[str, dict[str, FuncInfo]] = {}
        self.bases: dict[str, list[str]] = {}
        self.mod_funcs: dict[tuple[str, str], FuncInfo] = {}
        self.functions: dict[str, FuncInfo] = {}
        for m in modules:
            for node in m.tree.body:
                if isinstance(node, ast.ClassDef):
                    meths = self.classes.setdefault(node.name, {})
                    self.bases.setdefault(node.name, [
                        b.id for b in node.bases
                        if isinstance(b, ast.Name)])
                    for sub in node.body:
                        if isinstance(sub, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                            fi = FuncInfo(
                                f"{m.modname}.{node.name}.{sub.name}",
                                sub, m, node.name)
                            meths.setdefault(sub.name, fi)
                            self.functions[fi.key] = fi
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    fi = FuncInfo(f"{m.modname}.{node.name}", node, m,
                                  None)
                    self.mod_funcs[(m.modname, node.name)] = fi
                    self.functions[fi.key] = fi

    def method(self, cls: str, name: str,
               _seen: frozenset = frozenset()) -> FuncInfo | None:
        if cls in _seen or cls not in self.classes:
            return None
        if name in self.classes[cls]:
            return self.classes[cls][name]
        for b in self.bases.get(cls, ()):
            hit = self.method(b, name, _seen | {cls})
            if hit is not None:
                return hit
        return None


# ---------------------------------------------------------- lock summaries
@dataclass
class FuncSummary:
    acquires: set = field(default_factory=set)   # lock names (transitive)
    blocks: bool = False
    opaque: bool = False                         # may invoke a callback

    def merge(self, other: "FuncSummary") -> bool:
        before = (len(self.acquires), self.blocks, self.opaque)
        self.acquires |= other.acquires
        self.blocks = self.blocks or other.blocks
        self.opaque = self.opaque or other.opaque
        return (len(self.acquires), self.blocks,
                self.opaque) != before


def _attr_chain(node: ast.AST) -> list[str] | None:
    """['self', 'store', '_lock'] for self.store._lock; None if not a
    pure name/attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


class FunctionWalker:
    """Walks one function body maintaining the held-lock set.

    Subclasses hook ``on_acquire`` / ``on_call`` / ``on_blocking`` /
    ``on_access``; the walker handles with-regions, local lock/object
    aliases, nested function definitions (visited with the held set at
    their *call* sites), and ``# requires-lock:`` seeding.
    """

    def __init__(self, cfg: AnalysisConfig, index: PackageIndex,
                 fi: FuncInfo):
        self.cfg = cfg
        self.index = index
        self.fi = fi
        self.mod = fi.module
        self.aliases: dict[str, list[str]] = {}   # local -> attr chain
        self.nested: dict[str, ast.FunctionDef] = {}
        self.held: list[str] = []

    # hooks -------------------------------------------------------------
    def on_acquire(self, lockname: str, node: ast.AST) -> None: ...

    def on_call(self, target: FuncInfo, node: ast.AST) -> None: ...

    def on_opaque_call(self, desc: str, node: ast.AST) -> None: ...

    def on_blocking(self, desc: str, node: ast.AST) -> None: ...

    def on_access(self, attr: str, is_store: bool,
                  node: ast.AST) -> None: ...

    # resolution --------------------------------------------------------
    def _self_attr(self, node: ast.AST) -> str | None:
        """'x' for self.x, or for a local alias of self.x."""
        chain = _attr_chain(node)
        if chain is None:
            return None
        if chain[0] in self.aliases:
            chain = self.aliases[chain[0]] + chain[1:]
        if len(chain) == 2 and chain[0] == "self":
            return chain[1]
        return None

    def _lock_of(self, node: ast.AST):
        """LockSpec for a with-item / receiver expression, or None."""
        chain = _attr_chain(node)
        if chain is None:
            return None
        if chain[0] in self.aliases:
            chain = self.aliases[chain[0]] + chain[1:]
        # self._mutate  /  self.store._lock — bind by (module, attr)
        return self.cfg.resolve_attr(self.mod.modname, chain[-1])

    def _callee(self, func: ast.AST) -> FuncInfo | None:
        """Resolve a call target into the package index."""
        if isinstance(func, ast.Name):
            if func.id in self.nested:
                return FuncInfo(f"{self.fi.key}.<{func.id}>",
                                self.nested[func.id], self.mod,
                                self.fi.cls)
            return self.index.mod_funcs.get((self.mod.modname, func.id))
        if isinstance(func, ast.Attribute):
            chain = _attr_chain(func)
            if chain is None:
                return None
            if chain[0] in self.aliases:
                chain = self.aliases[chain[0]] + chain[1:]
            if chain[0] != "self":
                return None
            if len(chain) == 2 and self.fi.cls:
                return self.index.method(self.fi.cls, chain[1])
            if len(chain) == 3:
                for cls in self.cfg.attr_types.get(
                        (self.mod.modname, chain[1]), ()):
                    hit = self.index.method(cls, chain[2])
                    if hit is not None:
                        return hit
        return None

    # walking -----------------------------------------------------------
    def run(self) -> None:
        node = self.fi.node
        # `# requires-lock: _attr` on the def line seeds the held set
        for ln in range(node.lineno,
                        node.body[0].lineno if node.body else node.lineno):
            c = self.mod.comment(ln)
            if REQUIRES_TOKEN in c:
                attr = c.split(REQUIRES_TOKEN, 1)[1].strip().split()[0]
                spec = self.cfg.resolve_attr(self.mod.modname, attr)
                if spec is not None:
                    self.held.append(spec.name)
        for sub in ast.walk(node):
            if isinstance(sub, ast.FunctionDef) and sub is not node:
                self.nested[sub.name] = sub
        self._stmts(node.body)

    def _stmts(self, body: list) -> None:
        for st in body:
            self._stmt(st)

    def _stmt(self, st: ast.stmt) -> None:
        if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                and isinstance(st.targets[0], ast.Name):
            chain = _attr_chain(st.value)
            if chain is not None and chain[0] == "self":
                self.aliases[st.targets[0].id] = chain
        if isinstance(st, (ast.With, ast.AsyncWith)):
            self._with(st)
            return
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                      # visited at call sites
        for name, value in ast.iter_fields(st):
            if name in ("body", "orelse", "finalbody"):
                self._stmts(value)
            elif name == "handlers":
                for h in value:
                    self._stmts(h.body)
            elif isinstance(value, ast.expr):
                self._expr(value)
            elif isinstance(value, list):
                for v in value:
                    if isinstance(v, ast.expr):
                        self._expr(v)

    def _with(self, st: ast.With) -> None:
        pushed = 0
        for item in st.items:
            ce = item.context_expr
            spec = self._lock_of(ce)
            if spec is not None:
                self.on_acquire(spec.name, ce)
                self.held.append(spec.name)
                pushed += 1
                continue
            if isinstance(ce, ast.Call):
                self._expr(ce)
                # `with self._write_gate():` — gate holds for the body
                names = None
                if isinstance(ce.func, ast.Attribute):
                    names = self.cfg.with_funcs.get(ce.func.attr)
                elif isinstance(ce.func, ast.Name):
                    names = self.cfg.with_funcs.get(ce.func.id)
                for nm in names or ():
                    self.on_acquire(nm, ce)
                    self.held.append(nm)
                    pushed += 1
            else:
                self._expr(ce)
        self._stmts(st.body)
        for _ in range(pushed):
            self.held.pop()

    def _expr(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._call(sub)
            elif isinstance(sub, ast.Attribute) \
                    and isinstance(sub.ctx, (ast.Load, ast.Store)):
                attr = self._self_attr(sub)
                if attr is not None:
                    self.on_access(attr, isinstance(sub.ctx, ast.Store),
                                   sub)

    def _call(self, node: ast.Call) -> None:
        func = node.func
        # blocking by shape: time.sleep / sleep_us / x.join / ev.wait
        if isinstance(func, ast.Name) and func.id in BLOCKING_NAMES:
            self.on_blocking(func.id, node)
        elif isinstance(func, ast.Attribute):
            if func.attr in RPC_BLOCKING_ATTRS:
                self.on_blocking(f"endpoint RPC .{func.attr}()", node)
                for nm in RPC_IMPLIED_LOCKS:
                    if nm in self.cfg.by_name:
                        self.on_acquire(nm, node)
                return
            if func.attr in BLOCKING_ATTRS:
                # waiting on a condition you HOLD releases it — that is
                # the cv protocol, not a blocking call under the lock
                spec = self._lock_of(func.value)
                if not (spec is not None and spec.name in self.held):
                    self.on_blocking(f".{func.attr}()", node)
            # x.acquire() outside a with: treated as an ordering event
            if func.attr == "acquire":
                spec = self._lock_of(func.value)
                if spec is not None:
                    self.on_acquire(spec.name, node)
        target = self._callee(func)
        if target is not None:
            self.on_call(target, node)
            return
        # opaque callback: a local/parameter name holding `self.<attr>`
        # that is not a resolvable method (e.g. a transition hook)
        if isinstance(func, ast.Name) and func.id in self.aliases:
            chain = self.aliases[func.id]
            if len(chain) == 2 and chain[0] == "self":
                self.on_opaque_call(f"callback self.{chain[1]}", node)


def build_summaries(cfg: AnalysisConfig, index: PackageIndex
                    ) -> dict[str, FuncSummary]:
    """Fixed-point lock summaries over the intra-package call graph:
    which locks a call to each function may acquire (transitively) and
    whether it may block."""

    class _Collector(FunctionWalker):
        def __init__(self, cfg, index, fi, summaries):
            super().__init__(cfg, index, fi)
            self.summaries = summaries
            self.out = FuncSummary()

        def on_acquire(self, lockname, node):
            self.out.acquires.add(lockname)

        def on_blocking(self, desc, node):
            self.out.blocks = True

        def on_opaque_call(self, desc, node):
            self.out.opaque = True

        def on_call(self, target, node):
            if target.key in self.summaries:
                self.out.merge(self.summaries[target.key])
            elif target.node is not self.fi.node:
                # nested function: collect inline with a sub-walker
                sub = _Collector(self.cfg, self.index, target,
                                 self.summaries)
                sub.run()
                self.out.merge(sub.out)

    summaries = {k: FuncSummary() for k in index.functions}
    for _ in range(12):                 # call-graph depth bound
        changed = False
        for key, fi in index.functions.items():
            w = _Collector(cfg, index, fi, summaries)
            try:
                w.run()
            except RecursionError:
                continue
            changed |= summaries[key].merge(w.out)
        if not changed:
            break
    return summaries


# --------------------------------------------------------------- baseline
def load_baseline(path: Path) -> set[str]:
    """Baseline entries: one ``<rule>:<func>:<attr-or-detail>`` key per
    line; ``#`` comments carry the per-entry justification."""
    if not path.exists():
        return set()
    out = set()
    for line in path.read_text().splitlines():
        line = line.split("#", 1)[0].strip()
        if line:
            out.add(line)
    return out


def apply_baseline(findings: list[Finding], baseline: set[str]
                   ) -> list[Finding]:
    for f in findings:
        if f.key() in baseline:
            f.suppressed = True
    return findings
