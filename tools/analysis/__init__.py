"""Repo-aware static analysis passes over ``src/repro``.

Entry point: ``tools/analyze.py``.  The passes:

  * ``lockorder``  — LO001..LO006 against ``repro.concurrency.LOCK_ORDER``
  * ``guarded``    — GB001/GB002 for ``# guarded-by:`` annotations
  * ``threads``    — TL001..TL003 thread-lifecycle lint
  * ``rpcsurface`` — RPC001..RPC004 ShardService surface consistency

Shared AST machinery (module loading, class/call-graph index, the
held-lock-set walker, findings, baselines) lives in ``core``.
"""
from . import core, guarded, lockorder, rpcsurface, threads  # noqa: F401
