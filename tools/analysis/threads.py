"""Thread-lifecycle lint.

Rules:

  * **TL001** a ``threading.Thread`` that is neither ``daemon=True``
    nor provably ``.join()``ed (by the name/attribute it was assigned
    to, anywhere in the module).
  * **TL002** a thread target (resolved within the module) that loops
    (``while``/``for``) without consulting a stop ``Event``
    (``.is_set()`` / ``<stop>.wait(...)``) — an unstoppable loop.
  * **TL003** a thread stored on ``self`` (a persistent worker) created
    without ``name=`` — anonymous workers make stacks and the runtime
    witness unreadable.

Suppress a line with ``# lock-order: ok <reason>`` (shared token).
"""
from __future__ import annotations

import ast

from .core import (AnalysisConfig, Finding, ModuleInfo, SUPPRESS_TOKEN,
                   _attr_chain)


def _is_thread_ctor(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr == "Thread"
            and isinstance(f.value, ast.Name)
            and f.value.id == "threading") or \
           (isinstance(f, ast.Name) and f.id == "Thread")


def _kw(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _target_func(call: ast.Call, mod: ModuleInfo):
    """Resolve ``target=self._worker`` / ``target=loop`` to a function
    node within the module."""
    tgt = _kw(call, "target")
    if tgt is None:
        return None
    names = []
    chain = _attr_chain(tgt)
    if chain:
        names.append(chain[-1])
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef) and node.name in names:
            return node
    return None


def _loops_without_stop(fn: ast.FunctionDef) -> ast.stmt | None:
    """First unbounded-looking loop that never consults a stop event."""
    src_names = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.While, ast.For)):
            ok = False
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and isinstance(
                        sub.func, ast.Attribute) and sub.func.attr in (
                            "is_set", "wait"):
                    ok = True
                if isinstance(sub, ast.Attribute) and "stop" in sub.attr:
                    ok = True
                if isinstance(sub, (ast.Break, ast.Return, ast.Raise)):
                    ok = True           # bounded by an explicit exit
            if isinstance(node, ast.While) and not ok:
                return node
    del src_names
    return None


def run(cfg: AnalysisConfig, modules: list[ModuleInfo]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules:
        joined: set[str] = set()        # names .join() is called on
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute) and node.func.attr == "join":
                chain = _attr_chain(node.func.value)
                if chain:
                    joined.add(chain[-1])
                elif isinstance(node.func.value, ast.Subscript):
                    # `self._rebuild_threads[s].join()` etc. — credit the
                    # container attribute
                    inner = _attr_chain(node.func.value.value)
                    if inner:
                        joined.add(inner[-1])
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.value, ast.Call)
                    and _is_thread_ctor(node.value)):
                continue
            call = node.value
            line = node.lineno
            suppressed = SUPPRESS_TOKEN in mod.comment(line)
            tgt = node.targets[0]
            chain = _attr_chain(tgt)
            bind = chain[-1] if chain else None
            persistent = chain is not None and chain[0] == "self"
            daemon = _kw(call, "daemon")
            is_daemon = isinstance(daemon, ast.Constant) \
                and daemon.value is True

            def emit(rule, msg):
                findings.append(Finding(rule, mod.rel, line, "", msg,
                                        suppressed=suppressed))

            if not is_daemon and (bind is None or bind not in joined):
                emit("TL001", f"non-daemon Thread bound to "
                     f"{bind or '<expr>'} is never joined in this "
                     f"module")
            if persistent and _kw(call, "name") is None:
                emit("TL003", f"persistent worker self.{bind} created "
                     f"without name=")
            fn = _target_func(call, mod)
            if fn is not None:
                loop = _loops_without_stop(fn)
                if loop is not None:
                    findings.append(Finding(
                        "TL002", mod.rel, loop.lineno, fn.name,
                        f"thread target {fn.name} loops without "
                        f"checking a stop Event",
                        suppressed=SUPPRESS_TOKEN in
                        mod.comment(loop.lineno)))
    return findings
