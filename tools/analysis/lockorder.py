"""Lock-order pass: check every acquisition in the package against the
``LOCK_ORDER`` registry rank order.

Rules:

  * **LO001** rank inversion — acquiring a lock whose rank is below a
    lock already held (directly, or transitively through a resolved
    intra-package call).  Rank order is total, so passing LO001
    everywhere also proves the acquisition graph acyclic.
  * **LO002** re-acquiring a held non-reentrant lock (self-deadlock).
  * **LO003** acquiring any lock — or invoking an opaque callback —
    while holding a LEAF lock.
  * **LO004** blocking call (endpoint RPC, ``sleep``/``sleep_us``,
    ``join``, ``Event.wait``) while holding a LEAF lock.
  * **LO005** a ``threading`` lock/condition/semaphore assigned to a
    ``self`` attribute that the registry does not name.
  * **LO006** exclusion pair (``NEVER_TOGETHER``) held together.

Suppress a single line with ``# lock-order: ok <reason>``; sanctioned
edges live in ``repro.concurrency.SANCTIONED_EDGES``.
"""
from __future__ import annotations

import ast

from .core import (AnalysisConfig, Finding, FunctionWalker, ModuleInfo,
                   PackageIndex, SUPPRESS_TOKEN, build_summaries)

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore"}


class _Checker(FunctionWalker):
    def __init__(self, cfg, index, fi, summaries, findings):
        super().__init__(cfg, index, fi)
        self.summaries = summaries
        self.findings = findings

    # ------------------------------------------------------------ helpers
    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        line = getattr(node, "lineno", self.fi.node.lineno)
        f = Finding(rule, self.fi.module.rel, line, self.fi.key, msg)
        if SUPPRESS_TOKEN in self.fi.module.comment(line):
            f.suppressed = True
        self.findings.append(f)

    def _check_edge(self, new: str, node: ast.AST,
                    via: str | None = None) -> None:
        tail = f" (via {via})" if via else ""
        nspec = self.cfg.by_name[new]
        for held in self.held:
            if frozenset({held, new}) in self.cfg.never_together:
                if held != new:
                    self._emit("LO006", node,
                               f"exclusion pair held together: {held} "
                               f"with {new}{tail}")
                continue
            if held == new:
                if via is None and not nspec.reentrant \
                        and new not in self.cfg.same_name_ok:
                    self._emit("LO002", node,
                               f"re-acquiring non-reentrant {new} "
                               f"already held{tail}")
                continue
            if (held, new) in self.cfg.sanctioned:
                continue
            hspec = self.cfg.by_name[held]
            if hspec.leaf:
                self._emit("LO003", node,
                           f"acquires {new} while holding LEAF "
                           f"{held}{tail}")
            elif hspec.rank > nspec.rank:
                self._emit("LO001", node,
                           f"rank inversion: acquires {new} (rank "
                           f"{nspec.rank}) while holding {held} (rank "
                           f"{hspec.rank}){tail}")

    # -------------------------------------------------------------- hooks
    def on_acquire(self, lockname, node):
        self._check_edge(lockname, node)

    def on_blocking(self, desc, node):
        for held in self.held:
            if self.cfg.by_name[held].leaf:
                self._emit("LO004", node,
                           f"blocking call {desc} while holding LEAF "
                           f"{held}")

    def on_opaque_call(self, desc, node):
        for held in self.held:
            if self.cfg.by_name[held].leaf:
                self._emit("LO003", node,
                           f"opaque {desc} invoked while holding LEAF "
                           f"{held} (a callback may acquire anything)")

    def on_call(self, target, node):
        if not self.held:
            return
        summ = self.summaries.get(target.key)
        if summ is None:
            # nested function: summarize on the fly
            sub_summaries = dict(self.summaries)
            from .core import FuncSummary
            probe = _Collector(self.cfg, self.index, target,
                               sub_summaries)
            summ = FuncSummary()
            try:
                probe.run()
                summ = probe.out
            except RecursionError:
                return
        for lockname in sorted(summ.acquires):
            if lockname in self.held and \
                    self.cfg.by_name[lockname].reentrant:
                continue
            self._check_edge(lockname, node, via=target.key)
        if summ.blocks:
            self.on_blocking(f"call into {target.key}", node)
        if summ.opaque:
            self.on_opaque_call(f"callback via {target.key}", node)


class _Collector(FunctionWalker):
    """Summary collector for nested functions hit during checking."""

    def __init__(self, cfg, index, fi, summaries):
        super().__init__(cfg, index, fi)
        self.summaries = summaries
        from .core import FuncSummary
        self.out = FuncSummary()

    def on_acquire(self, lockname, node):
        self.out.acquires.add(lockname)

    def on_blocking(self, desc, node):
        self.out.blocks = True

    def on_opaque_call(self, desc, node):
        self.out.opaque = True

    def on_call(self, target, node):
        if target.key in self.summaries:
            self.out.merge(self.summaries[target.key])


def _check_registered(cfg: AnalysisConfig, mod: ModuleInfo,
                      findings: list) -> None:
    """LO005: every threading primitive assigned to a self attribute
    must be a registered site (or a registered alias like _mig_cv)."""
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"):
            continue
        call = node.value
        # peel `witness_lock("name", threading.X(...))` wrappers
        if isinstance(call, ast.Call) and isinstance(
                call.func, (ast.Name, ast.Attribute)):
            fname = call.func.id if isinstance(call.func, ast.Name) \
                else call.func.attr
            if fname in ("witness_lock", "witness_condition") \
                    and len(call.args) == 2:
                call = call.args[1]
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and isinstance(call.func.value, ast.Name)
                and call.func.value.id == "threading"
                and call.func.attr in _LOCK_CTORS):
            continue
        if cfg.resolve_attr(mod.modname, tgt.attr) is None:
            f = Finding("LO005", mod.rel, node.lineno, "",
                        f"threading.{call.func.attr} assigned to "
                        f"self.{tgt.attr} is not in the LOCK_ORDER "
                        f"registry")
            if SUPPRESS_TOKEN in mod.comment(node.lineno):
                f.suppressed = True
            findings.append(f)


def run(cfg: AnalysisConfig, modules: list[ModuleInfo]) -> list[Finding]:
    index = PackageIndex(modules)
    summaries = build_summaries(cfg, index)
    findings: list[Finding] = []
    for mod in modules:
        _check_registered(cfg, mod, findings)
    for fi in index.functions.values():
        w = _Checker(cfg, index, fi, summaries, findings)
        try:
            w.run()
        except RecursionError:
            pass
    # comprehension-based semaphore lists (`self._windows = [...]`) are
    # not Call nodes — LO005 intentionally sees only direct ctor calls.
    return findings
