"""RPC-surface pass: the ``ShardService`` command vocabulary must stay
consistent across the server dispatch table and both endpoint clients.

Rules:

  * **RPC001** a string-literal method name at a call site
    (``.call("x")`` / ``.call_submit("x")`` / a ``(shard, "x", kwargs)``
    round tuple) that is not a public ``ShardService`` method — a typo
    would only explode at runtime on the remote path.
  * **RPC002** a public ``ShardService`` method no call site in the
    repo ever invokes and that is not in the reviewed
    ``surface_only`` table (operator/diagnostic RPCs) — dead surface.
  * **RPC003** a public ``ShardService`` method whose parameter
    defaults are not wire-type constants (None/bool/int/float/str) —
    the serializer vocabulary cannot round-trip them.
  * **RPC004** a ``RopShardEndpoint`` result path (``call`` /
    ``call_result`` / ``fetch_result``) that does not map remote
    errors through ``_map_error`` — remote ``DeviceFailedError`` would
    lose its type and the supervisor its failure signal.
"""
from __future__ import annotations

import ast

from .core import AnalysisConfig, Finding, ModuleInfo

SERVICE_CLASS = "ShardService"
ROP_CLASS = "RopShardEndpoint"
ERROR_MAPPED = ("call", "call_result", "fetch_result")

# Public service methods that are intentionally operator/diagnostic
# surface (invoked from examples, benchmarks or tests — not from the
# coordinator's own code paths).  Reviewed: each carries its reason.
SURFACE_ONLY: dict[str, str] = {
    "export_adjacency": "oracle/validation dump used by tests only",
    "fail": "fault-injection drill entry point (examples/CI)",
    "clear_cache": "operator cache reset (examples/benchmarks)",
}


def _service_class(modules: list[ModuleInfo]):
    for mod in modules:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef) and \
                    node.name == SERVICE_CLASS:
                return mod, node
    return None, None


def _collect_call_names(modules: list[ModuleInfo]) -> dict[str, list]:
    """method-name -> [(rel, line), ...] from endpoint call sites."""
    used: dict[str, list] = {}

    def note(name, mod, node):
        used.setdefault(name, []).append((mod.rel, node.lineno))

    for mod in modules:
        # the bare (shard, "name", kwargs) round-tuple idiom only lives
        # in the store/serve layers; applying it repo-wide would snag
        # unrelated 3-tuples with a string member.
        tuples_ok = "store/" in mod.rel or "serve/" in mod.rel
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute) and node.func.attr in (
                        "call", "call_submit") and node.args:
                a0 = node.args[0]
                if isinstance(a0, ast.Constant) and isinstance(
                        a0.value, str) and a0.value.isidentifier():
                    note(a0.value, mod, a0)
            elif tuples_ok and isinstance(node, ast.Tuple) \
                    and len(node.elts) == 3:
                mid = node.elts[1]
                if isinstance(mid, ast.Constant) \
                        and isinstance(mid.value, str) \
                        and mid.value.isidentifier() \
                        and isinstance(node.elts[2],
                                       (ast.Dict, ast.Call, ast.Name)):
                    note(mid.value, mod, mid)
    return used


_WIRE_CONST = (type(None), bool, int, float, str)


def _folds_to_wire_const(node: ast.AST) -> bool:
    """True for expressions built purely from wire-type constants
    (``1 << 18``, ``-1``, ``60.0 * 5`` ...)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, _WIRE_CONST)
    if isinstance(node, ast.UnaryOp):
        return _folds_to_wire_const(node.operand)
    if isinstance(node, ast.BinOp):
        return _folds_to_wire_const(node.left) \
            and _folds_to_wire_const(node.right)
    return False


def run(cfg: AnalysisConfig, modules: list[ModuleInfo],
        extra_modules: list[ModuleInfo] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    svc_mod, svc = _service_class(modules)
    if svc is None:
        return findings
    public = {n.name: n for n in svc.body
              if isinstance(n, ast.FunctionDef)
              and not n.name.startswith("_")}

    used = _collect_call_names(modules)
    used_everywhere = dict(used)
    for name, sites in _collect_call_names(extra_modules or []).items():
        used_everywhere.setdefault(name, []).extend(sites)

    # RPC001 — call-site vocabulary must resolve to the service surface
    for name, sites in used.items():
        if name not in public:
            for rel, line in sites:
                findings.append(Finding(
                    "RPC001", rel, line, "",
                    f'call site names "{name}", which is not a public '
                    f"{SERVICE_CLASS} method"))

    # RPC002 — the surface must be reachable (or reviewed surface-only)
    for name, node in public.items():
        if name not in used_everywhere and name not in SURFACE_ONLY:
            findings.append(Finding(
                "RPC002", svc_mod.rel, node.lineno,
                f"{svc_mod.modname}.{SERVICE_CLASS}.{name}",
                f"public service method {name} has no call site and no "
                f"surface_only entry"))

    # RPC003 — wire-safe parameter defaults.  A module-level
    # `_NAME = <constant>` counts: it serializes like the literal.
    mod_consts: set[str] = set()
    for stmt in svc_mod.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and _folds_to_wire_const(stmt.value):
            mod_consts.add(stmt.targets[0].id)
    for name, node in public.items():
        args = node.args
        for d in list(args.defaults) + [d for d in args.kw_defaults
                                        if d is not None]:
            ok = (isinstance(d, ast.Constant)
                  and isinstance(d.value, _WIRE_CONST)) \
                or (isinstance(d, ast.Name) and d.id in mod_consts)
            if not ok:
                findings.append(Finding(
                    "RPC003", svc_mod.rel, d.lineno,
                    f"{svc_mod.modname}.{SERVICE_CLASS}.{name}",
                    f"parameter default in {name} is not a wire-type "
                    f"constant (None/bool/int/float/str)"))
        if args.vararg is not None or args.kwarg is not None:
            findings.append(Finding(
                "RPC003", svc_mod.rel, node.lineno,
                f"{svc_mod.modname}.{SERVICE_CLASS}.{name}",
                f"{name} takes *args/**kwargs — not an explicit wire "
                f"signature"))

    # RPC004 — RoP result paths route errors through _map_error
    for mod in modules:
        for node in mod.tree.body:
            if not (isinstance(node, ast.ClassDef)
                    and node.name == ROP_CLASS):
                continue
            for meth in node.body:
                if not (isinstance(meth, ast.FunctionDef)
                        and meth.name in ERROR_MAPPED):
                    continue
                mapped = any(
                    isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr == "_map_error"
                    for h in [x for x in ast.walk(meth)
                              if isinstance(x, ast.ExceptHandler)]
                    for sub in ast.walk(h))
                if not mapped:
                    findings.append(Finding(
                        "RPC004", mod.rel, meth.lineno,
                        f"{mod.modname}.{ROP_CLASS}.{meth.name}",
                        f"{meth.name} does not map remote errors via "
                        f"_map_error in an except handler"))
    return findings
