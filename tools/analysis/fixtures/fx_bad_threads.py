"""Seeded thread-lifecycle violations."""
import threading
import time


class BadThreads:
    def __init__(self):
        self._stop = threading.Event()
        # non-daemon, never joined anywhere in this module, and a
        # persistent self-bound worker with no name
        self._t = threading.Thread(target=self._spin)  # expect: TL001, TL003

    def _spin(self):
        while True:                         # expect: TL002
            time.sleep(0.01)
