"""Seeded RPC-surface violations around a miniature service/client
pair shaped like the real ``ShardService`` / ``RopShardEndpoint``."""


class ShardService:
    def __init__(self, store):
        self.store = store

    def ok_method(self, vid) -> dict:
        return {"vid": int(vid)}

    def dead_method(self) -> dict:          # expect: RPC002
        return {}

    def bad_default(self, rows=[]) -> dict:  # expect: RPC003
        return {"n": len(rows)}

    def var_args(self, *args) -> dict:      # expect: RPC003
        return {}


class RopShardEndpoint:
    def __init__(self, client):
        self.client = client

    def _map_error(self, e):
        return RuntimeError(str(e))

    def call(self, method, **kwargs):       # expect: RPC004
        return self.client.call(method, **kwargs)

    def call_result(self, handle):
        try:
            return self.client.result(handle)
        except RuntimeError as e:
            raise self._map_error(e) from None

    def fetch_result(self, handle):
        try:
            return self.client.result(handle)
        except RuntimeError as e:
            raise self._map_error(e) from None


def caller(ep):
    ep.call("ok_method", vid=1)
    ep.call("bad_default")
    ep.call("var_args")
    ep.call("missing_method")               # expect: RPC001
