"""Known-good fixture: every tricky-but-legal idiom the lock-order,
guarded-by and thread-lifecycle passes must NOT flag."""
import threading
import time


class Good:
    def __init__(self):
        self._a = threading.Lock()          # rank 10
        self._b = threading.Lock()          # rank 20
        self._r = threading.RLock()         # rank 25, reentrant
        self._leaf = threading.Lock()       # rank 30, LEAF
        self._mu = threading.Lock()         # rank 40
        self._state = 0                     # guarded-by: _mu
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="good-worker")

    # ascending rank nesting is legal
    def ordered(self):
        with self._a:
            with self._b:
                pass

    # re-entrant re-acquisition of an RLock is legal
    def reenter(self):
        with self._r:
            self._reenter_inner()

    def _reenter_inner(self):
        with self._r:
            pass

    # a local alias of a lock attribute still resolves
    def aliased(self):
        mu = self._mu
        with mu:
            self._state += 1

    # blocking is fine while holding a NON-leaf lock
    def block_under_nonleaf(self):
        with self._b:
            time.sleep(0.001)

    # leaf lock held for a tiny critical section only
    def leaf_ok(self):
        with self._leaf:
            x = 1
        return x

    # a helper documented as called-with-lock-held
    def locked_path(self):
        with self._mu:
            self._mutate_locked()

    def _mutate_locked(self):  # requires-lock: _mu
        self._state += 1

    # transitive: calling a helper that takes a HIGHER-ranked lock
    def transitive_ok(self):
        with self._a:
            self._takes_b()

    def _takes_b(self):
        with self._b:
            pass

    def _worker(self):
        while not self._stop.is_set():
            time.sleep(0.001)
