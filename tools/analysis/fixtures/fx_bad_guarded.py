"""Seeded guarded-by violations."""
import threading


class BadGuarded:
    def __init__(self):
        self._mu = threading.Lock()         # rank 40
        self._count = 0                     # guarded-by: _mu

    def locked_write(self):
        with self._mu:
            self._count += 1                # fine

    def unlocked_write(self):
        self._count += 1                    # expect: GB001

    def unlocked_read(self):
        return self._count                  # expect: GB002

    def reviewed_read(self):
        return self._count                  # unguarded-ok: fixture test
