"""Seeded lock-order violations.  Each violating line carries an
``# expect: <rule>`` marker the test harness reads back."""
import threading
import time


class Bad:
    def __init__(self):
        self._a = threading.Lock()          # rank 10
        self._b = threading.Lock()          # rank 20
        self._leaf = threading.Lock()       # rank 30, LEAF
        self._x = threading.Lock()          # rank 50 (exclusion with _y)
        self._y = threading.Lock()          # rank 60 (exclusion with _x)
        self._rogue = threading.Lock()      # expect: LO005
        self.cb = None

    def inversion(self):
        with self._b:
            with self._a:                   # expect: LO001
                pass

    def reacquire(self):
        with self._a:
            with self._a:                   # expect: LO002
                pass

    def acquire_under_leaf(self):
        with self._leaf:
            with self._x:                   # expect: LO003
                pass

    def callback_under_leaf(self):
        hook = self.cb
        with self._leaf:
            hook()                          # expect: LO003

    def block_under_leaf(self):
        with self._leaf:
            time.sleep(0.01)                # expect: LO004

    def exclusion(self):
        with self._x:
            with self._y:                   # expect: LO006
                pass

    # the inversion must also be caught THROUGH a call
    def transitive_inversion(self):
        with self._b:
            self._takes_a()                 # expect: LO001

    def _takes_a(self):
        with self._a:
            pass

    # ...and a transitive blocking call under a leaf
    def transitive_block(self):
        with self._leaf:
            self._sleeps()                  # expect: LO004

    def _sleeps(self):
        time.sleep(0.01)

    # suppression: same inversion, reviewed inline
    def suppressed_inversion(self):
        with self._b:
            with self._a:                   # lock-order: ok fixture test
                pass
