"""Seeded fixture corpus for ``tests/test_analysis.py``.

``fx_bad_*`` modules seed exactly the violations their ``# expect:``
comments name; ``fx_good.py`` exercises the trickier clean idioms
(aliases, re-entrancy, requires-lock, transitive calls) and must
produce ZERO findings.  These files are parsed by the analyzer, never
imported or executed.
"""
