"""End-to-end driver (paper-kind = inference service): serve a stream of
batched GNN inference requests against a near-storage graph, with live
mutable updates interleaved — the deployment scenario of the paper.

  PYTHONPATH=src python examples/serve_gnn.py [--requests 20]
"""
import argparse
import time

import numpy as np

from repro.core.service import HolisticGNNService, make_service_dfg
from repro.core import gnn
from repro.kernels.ops import program_config
from repro.rpc import RPCServer, RPCClient


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--model", default="gcn", choices=["gcn", "gin", "ngcf"])
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n, e, feat = 5000, 40000, 128
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)

    svc = HolisticGNNService(h_threshold=64, pad_to=64)
    client = RPCClient(RPCServer(svc))
    client.call("update_graph", edge_array=edges, embeddings=emb)
    program_config(svc.xbuilder, "hetero")

    params = gnn.init_params(args.model, [feat, 64, 32], seed=1)
    dfg = make_service_dfg(args.model, 2, [10, 10]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds(args.model, params, None, []).items()
               if k != "H"}

    lat = []
    for r in range(args.requests):
        targets = rng.integers(0, n, args.batch_size).tolist()
        t0 = time.perf_counter()
        out = client.call("run", dfg=dfg, batch=targets, weights=weights,
                          seed=r)
        lat.append(time.perf_counter() - t0)
        if r % 5 == 0:                       # live graph mutations mid-service
            client.call("add_edge", dst=int(rng.integers(0, n)),
                        src=int(rng.integers(0, n)))
    lat = np.array(lat) * 1e3
    print(f"{args.requests} requests x {args.batch_size} targets "
          f"({args.model}): p50={np.percentile(lat, 50):.1f} ms "
          f"p95={np.percentile(lat, 95):.1f} ms mean={lat.mean():.1f} ms")
    print(f"store: {svc.store.stats.pages_h} H-pages, "
          f"{svc.store.stats.pages_l} L-pages, "
          f"{svc.store.dev.stats.read_pages} page reads")


if __name__ == "__main__":
    main()
