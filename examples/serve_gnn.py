"""End-to-end driver (paper-kind = inference service): concurrent GNN
serving against a near-storage graph through the serving runtime —
multi-queue RoP, continuous request batching, and the device-DRAM
embedding cache — with mixed-priority traffic and live mutations.

Traffic mix per client round:
  * interactive clients submit high-priority requests with a deadline;
  * bulk clients submit best-effort requests that the scheduler coalesces
    into fused super-batches;
  * a mutator thread streams unit graph updates (add_edge / update_embed)
    through the same queues — mutations dispatch immediately, never stuck
    behind a model execution, and invalidate exactly the cached pages they
    touch.

With ``--replication R --kill-shard S`` the run doubles as a fault drill
(the CI fault-injection gate): once a third of the traffic has completed,
a chaos thread fails shard S mid-serve — requests keep completing from
the surviving replicas — then after the clients drain, a seeded reference
request is answered degraded, the shard is rebuilt from the survivors,
and the post-rebuild answer is asserted bit-identical to the degraded one
(the live mutator means there is no meaningful pre-failure reference;
healthy-vs-degraded bit-identity on a quiesced store is asserted by
``tests/test_replicated_store.py`` and ``benchmarks/fig24_replicated``).

With ``--chaos`` the drill goes autonomic: a shard's DEVICE is killed
directly mid-serve — no ``fail_shard``, no operator RPC of any kind —
and the attached ``ShardSupervisor`` must detect the fault on its own
(zero-traffic probe + serving-path error mapping), auto-drain, and
auto-rebuild back to full redundancy.  After the traffic drains the
mutator is quiesced and a second device kill asserts bit-identity end to
end: reference answer == degraded answer (auto-steering, still no
operator) == post-auto-rebuild answer.

With ``--remote-shards N`` the array is multi-host: every shard sits
behind its own RoP endpoint (``make_rop_endpoints`` — per-shard SQ/CQ
pairs + PCIeChannel mmap buffers + a shard-host poll thread), the
coordinator speaks only the ShardEndpoint protocol, and rebuild streams
survivor pages shard-to-shard over the peer links.  Results stay
bit-identical to the in-process array.

With ``--reshard-grow K`` (or ``--reshard-shrink K``) the run doubles as
an elastic drill: once a third of the traffic has completed, the array is
resharded LIVE — K shards attach (or the K highest-id shards drain out)
and only the vertex classes that change owner migrate over the peer
links, while the clients and the mutator keep running.  Combined with
``--kill-shard S`` the kill fires *mid-migration* (the chaos thread waits
for the copy windows to open) and the migration must complete from the
surviving replicas.  After the traffic drains, the mutated graph is
asserted bit-identical to a reference store that replays the acknowledged
op log serially — the array answered through attach, copy, flip and
detach without dropping or corrupting anything, with zero failed
requests.

With ``--firehose`` the bulk load goes through the distributed
device-side ingest (raw chunk streaming + shard-local sort/pack) and the
mutator's writes flow through an open ``MutationFirehose``: each time
window becomes ONE device-side ``apply_mutations`` command per shard
instead of one RPC per op.  After the traffic drains, the firehose is
flushed + closed and the mutated graph is asserted bit-identical to a
reference store that replays the exact op log one unit mutation at a
time — the serving answers mid-stream came from real window boundaries.

  PYTHONPATH=src python examples/serve_gnn.py [--requests 20] [--clients 8]
  PYTHONPATH=src python examples/serve_gnn.py --shards 3 --replication 2 \
      --kill-shard 1
  PYTHONPATH=src python examples/serve_gnn.py --remote-shards 3 \
      --replication 2 --chaos
  PYTHONPATH=src python examples/serve_gnn.py --shards 2 --firehose
"""
import argparse
import threading

import numpy as np

from repro.core.service import HolisticGNNService, make_service_dfg
from repro.core import gnn
from repro.kernels.ops import program_config
from repro.serve import HealthPolicy, ServingRuntime, ShardSupervisor
from repro.store import make_rop_endpoints


def _kill_device(store, s):
    """Chaos: kill the shard's device directly — the array is never told."""
    ep = store.endpoints[s]
    if hasattr(ep, "local_store"):
        ep.local_store.dev.fail()
    else:
        ep.host.service.store.dev.fail()


def _wait_healed(sup, store, deadline_s=120.0):
    import time
    t_end = time.perf_counter() + deadline_s
    while time.perf_counter() < t_end:
        snap = sup.snapshot()
        if (snap["incidents"] and not any(store.failed_shards)
                and all(s == "healthy" for s in snap["states"])):
            return snap
        time.sleep(0.02)
    raise AssertionError(f"array did not heal itself: {sup.snapshot()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20,
                    help="requests per client")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--model", default="gcn", choices=["gcn", "gin", "ngcf"])
    ap.add_argument("--shards", type=int, default=1,
                    help="CSSD array size: the graph is hash-partitioned "
                         "across N simulated devices (1 = single CSSD)")
    ap.add_argument("--remote-shards", type=int, default=None,
                    help="multi-host array: N shards each behind its own "
                         "RoP endpoint (per-shard SQ/CQ pair + host poll "
                         "thread) instead of in-process")
    ap.add_argument("--replication", type=int, default=1,
                    help="R-way replica placement across the array "
                         "(R >= 2 enables fail/rebuild)")
    ap.add_argument("--kill-shard", type=int, default=None,
                    help="fault injection: fail this shard once a third of "
                         "the traffic has completed, rebuild after drain")
    ap.add_argument("--chaos", action="store_true",
                    help="autonomic fault drill: kill a shard DEVICE "
                         "mid-serve with no operator RPC; the supervisor "
                         "must auto-detect, auto-drain and auto-rebuild")
    ap.add_argument("--firehose", action="store_true",
                    help="ingest drill: chunked distributed bulk load + "
                         "mutations batched through a MutationFirehose, "
                         "verified bit-identical to serial replay at exit")
    ap.add_argument("--reshard-grow", type=int, default=None, metavar="K",
                    help="elastic drill: grow the array by K shards LIVE "
                         "once a third of the traffic has completed; the "
                         "final graph is verified bit-identical to serial "
                         "replay")
    ap.add_argument("--reshard-shrink", type=int, default=None, metavar="K",
                    help="elastic drill: drain the K highest-id shards out "
                         "of the array live (same verification)")
    args = ap.parse_args()
    if args.kill_shard is not None and args.replication < 2:
        ap.error("--kill-shard needs --replication >= 2")
    if args.chaos and args.replication < 2:
        ap.error("--chaos needs --replication >= 2")
    if args.chaos and args.kill_shard is not None:
        ap.error("--chaos and --kill-shard are mutually exclusive")
    if args.remote_shards is not None and args.shards != 1:
        ap.error("--remote-shards and --shards are mutually exclusive")
    if args.firehose and (args.chaos or args.kill_shard is not None):
        ap.error("--firehose and the fault drills are mutually exclusive")
    reshard_drill = (args.reshard_grow is not None
                     or args.reshard_shrink is not None)
    if reshard_drill:
        n_arr = args.remote_shards if args.remote_shards is not None \
            else args.shards
        if args.reshard_grow is not None and args.reshard_shrink is not None:
            ap.error("--reshard-grow and --reshard-shrink are mutually "
                     "exclusive")
        if args.chaos or args.firehose:
            ap.error("the reshard drill composes with --kill-shard only")
        if n_arr < 2:
            ap.error("the reshard drill needs an array "
                     "(--shards/--remote-shards >= 2)")
        if args.reshard_shrink is not None and args.kill_shard is not None:
            ap.error("--reshard-shrink renumbers shards; combine "
                     "--kill-shard with --reshard-grow")
        if args.reshard_shrink is not None \
                and n_arr - args.reshard_shrink < max(1, args.replication):
            ap.error("--reshard-shrink would leave too few shards")

    rng = np.random.default_rng(0)
    n, e, feat = 5000, 40000, 128
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)

    endpoints = None
    if args.remote_shards is not None:
        endpoints = make_rop_endpoints(args.remote_shards, h_threshold=64)
    svc = HolisticGNNService(h_threshold=64, pad_to=64, cache_pages=4096,
                             n_shards=args.shards, endpoints=endpoints,
                             replication=args.replication,
                             stats_staleness_s=(0.01 if endpoints else 0.0))
    runtime = ServingRuntime(svc, n_queues=min(args.clients, 8),
                             max_group=16, max_pending=512)
    boot = runtime.client()
    runtime.start()
    boot.call("update_graph", edge_array=edges, embeddings=emb,
              chunked=args.firehose, timeout=600)
    program_config(svc.xbuilder, "hetero")
    if args.firehose:
        boot.call("open_firehose", window_s=0.01, timeout=600)

    supervisor = None
    if args.chaos:
        supervisor = ShardSupervisor(svc.store, HealthPolicy(
            probe_interval_s=0.01, rebuild_retry_s=0.1)).start()

    params = gnn.init_params(args.model, [feat, 64, 32], seed=1)
    dfg = make_service_dfg(args.model, 2, [10, 10]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds(args.model, params, None, []).items()
               if k != "H"}
    # deploy the model device-side once; requests then carry only targets
    boot.call("put_weights", name="deployed", weights=weights, timeout=600)

    lat = {"interactive": [], "bulk": []}
    errors = []
    lock = threading.Lock()
    stop_mutator = threading.Event()
    total_reqs = args.requests * args.clients

    def completed():
        with lock:
            return len(lat["interactive"]) + len(lat["bulk"]) + len(errors)

    killed = threading.Event()
    chaos_victim = 1
    reshard_started = threading.Event()
    reshard_report: dict = {}

    def reshard_loop():
        """Reshard the array live once a third of the traffic completed.

        Small chunks + pacing stretch the migration so the traffic (and,
        with --kill-shard, the kill) really lands mid-copy-window."""
        import time
        cl = runtime.client()
        deadline = time.perf_counter() + 120.0
        while completed() < total_reqs // 3 \
                and time.perf_counter() < deadline:
            time.sleep(0.01)
        reshard_started.set()
        kw = dict(chunk_pages=8, pace_s=0.002, timeout=600)
        if args.reshard_grow is not None:
            r = cl.call("reshard", add=args.reshard_grow, **kw)
        else:
            n0 = svc.store.n_shards
            r = cl.call("reshard",
                        remove=list(range(n0 - args.reshard_shrink, n0)),
                        **kw)
        reshard_report.update(r)
        print(f"reshard: {r['classes_moved']} classes moved "
              f"({r['copies']} copies, {r['bytes_shipped']} bytes over the "
              f"peer links) -> {r['n_shards']} shards in "
              f"{r['seconds'] * 1e3:.0f} ms, {r['epochs']} routing epochs")

    def chaos_loop():
        """Fail the victim shard once a third of the traffic completed —
        or, when composed with the reshard drill, mid-migration."""
        import time
        cl = runtime.client()
        if reshard_drill:
            reshard_started.wait(timeout=120.0)
            deadline = time.perf_counter() + 60.0
            while time.perf_counter() < deadline and not reshard_report:
                ps = svc.store.placement_stats()
                if ps["migrating_classes"]:
                    break                     # a copy window is open NOW
                time.sleep(0.001)
        else:
            deadline = time.perf_counter() + 120.0
            while completed() < total_reqs // 3 \
                    and time.perf_counter() < deadline:
                time.sleep(0.01)
        info = cl.call("fail_shard", shard=args.kill_shard, timeout=600)
        killed.set()
        print(f"chaos: failed shard {args.kill_shard} after {completed()} "
              f"requests (degraded classes {info['degraded_classes']})")

    def autonomic_chaos_loop():
        """Kill the victim DEVICE once a third of the traffic completed —
        no RPC: the supervisor has to notice."""
        import time
        deadline = time.perf_counter() + 120.0
        while completed() < total_reqs // 3 \
                and time.perf_counter() < deadline:
            time.sleep(0.01)
        _kill_device(svc.store, chaos_victim)
        killed.set()
        print(f"chaos: killed shard {chaos_victim}'s device after "
              f"{completed()} requests — no operator call issued")

    def client_loop(cid):
        import time
        cl = runtime.client()
        crng = np.random.default_rng(100 + cid)
        interactive = cid % 4 == 0            # every 4th client is latency-
        kind = "interactive" if interactive else "bulk"     # sensitive
        for r in range(args.requests):
            targets = crng.integers(0, n, args.batch_size).tolist()
            t0 = time.perf_counter()
            try:
                cl.call("run", dfg=dfg, batch=targets,
                        weights_ref="deployed", seed=cid * 1000 + r,
                        priority=10 if interactive else 0,
                        deadline_s=30.0 if interactive else None,
                        timeout=600)
            except Exception as e:  # noqa: BLE001 — surfaced at exit
                with lock:
                    errors.append(f"client {cid} req {r}: {e}")
                continue
            with lock:
                lat[kind].append(time.perf_counter() - t0)

    op_log = []                 # (kind, args) for the firehose replay check

    def mutator_loop():
        cl = runtime.client()
        mrng = np.random.default_rng(999)
        while not stop_mutator.is_set():
            dst, src = int(mrng.integers(0, n)), int(mrng.integers(0, n))
            vid = int(mrng.integers(0, n))
            vec = mrng.standard_normal(feat).astype(np.float32)
            try:
                cl.call("add_edge", dst=dst, src=src, timeout=600)
                op_log.append(("add_edge", dst, src))
                cl.call("update_embed", vid=vid, embed=vec, timeout=600)
                op_log.append(("update_embed", vid, vec))
            except Exception as e:  # noqa: BLE001 — surfaced at exit
                with lock:
                    errors.append(f"mutator: {e}")
            stop_mutator.wait(0.02)

    threads = [threading.Thread(target=client_loop, args=(c,))
               for c in range(args.clients)]
    mut = threading.Thread(target=mutator_loop)
    if reshard_drill:
        threads.append(threading.Thread(target=reshard_loop))
    if args.kill_shard is not None:
        threads.append(threading.Thread(target=chaos_loop))
    if args.chaos:
        threads.append(threading.Thread(target=autonomic_chaos_loop))
    for t in threads:
        t.start()
    mut.start()
    for t in threads:
        t.join()
    stop_mutator.set()
    mut.join()

    if args.firehose:
        # drain the window log, then prove the windowed application left
        # the EXACT graph a serial unit-mutation replay leaves: rebuild
        # the pre-mutation store locally and replay the acknowledged op
        # log one op at a time
        final = boot.call("flush_firehose", timeout=600)
        snap = boot.call("close_firehose", timeout=600)
        assert snap["applied"] == snap["submitted"] == len(op_log), \
            (snap, len(op_log))
        from repro.store import BlockDevice, GraphStore
        ref = GraphStore(BlockDevice(), h_threshold=64)
        ref.update_graph(edges, emb)
        for op in op_log:
            getattr(ref, op[0])(*op[1:])
        assert ref.to_adjacency() == svc.store.to_adjacency(), \
            "firehose graph diverged from serial replay"
        vids = np.arange(0, n, 17)
        assert (ref.get_embeds(vids) ==
                np.asarray(svc.store.get_embeds(vids))).all(), \
            "firehose embeddings diverged from serial replay"
        print(f"firehose drill: {snap['applied']} ops in "
              f"{snap['windows']} windows ({snap['barriers']} barriers, "
              f"{snap['shed']} shed, {final['applied_now']} at drain) — "
              f"bit-identical to serial replay")

    if args.kill_shard is not None:
        assert killed.is_set(), "chaos thread never fired"
        # the traffic has drained; the degraded answer and the post-rebuild
        # answer to the same seeded request must be bit-identical — the
        # rebuilt shard re-materialised exactly the survivors' state
        ref_req = dict(dfg=dfg, batch=list(range(8)),
                       weights_ref="deployed", seed=424242)
        degraded = boot.call("run", **ref_req, timeout=600)["Result"]
        st = boot.call("stats", timeout=600)
        assert st["replication"]["failed_shards"] == [args.kill_shard], st
        info = boot.call("rebuild_shard", shard=args.kill_shard, timeout=600)
        print(f"rebuild: shard {info['shard']} re-materialised "
              f"{info['vertices']} vertices / {info['pages_written']} pages "
              f"in {info['seconds'] * 1e3:.0f} ms")
        rebuilt = boot.call("run", **ref_req, timeout=600)["Result"]
        assert (np.asarray(degraded) == np.asarray(rebuilt)).all(), \
            "post-rebuild result diverged from degraded result"
        st = boot.call("stats", timeout=600)
        assert st["replication"]["failed_shards"] == [], st
        sh = st["shards"][args.kill_shard]
        assert sh["pages_l"] + sh["pages_h"] > 0 \
            and sh["device"]["written_pages"] > 0, sh
        print("fault drill: degraded serve + rebuild verified bit-identical")

    if reshard_drill:
        assert reshard_report, "reshard thread never completed"
        st = boot.call("stats", timeout=600)
        pl = st["placement"]
        assert not pl["resharding"] and not pl["migrating_classes"], pl
        n_expect = (n_arr + args.reshard_grow) \
            if args.reshard_grow is not None \
            else n_arr - args.reshard_shrink
        assert reshard_report["n_shards"] == n_expect \
            and svc.store.n_shards == n_expect, (reshard_report, n_expect)
        # the migrated, mutated-throughout graph must be EXACTLY the graph
        # a serial replay of the acknowledged op log leaves — the copy
        # windows, flips and detaches dropped / duplicated nothing
        from repro.store import BlockDevice, GraphStore
        ref = GraphStore(BlockDevice(), h_threshold=64)
        ref.update_graph(edges, emb)
        for op in op_log:
            getattr(ref, op[0])(*op[1:])
        vids = np.arange(0, n, 7)
        assert (np.asarray(svc.store.get_embeds(vids)) ==
                ref.get_embeds(vids)).all(), \
            "post-reshard embeddings diverged from serial replay"
        assert ref.to_adjacency() == svc.store.to_adjacency(), \
            "post-reshard graph diverged from serial replay"
        print(f"reshard drill: array now {n_expect} shards "
              f"({reshard_report['bytes_shipped']} bytes migrated, "
              f"{reshard_report['epochs']} epochs) — graph bit-identical "
              f"to serial replay after live migration")

    if args.chaos:
        assert killed.is_set(), "chaos thread never fired"
        # the supervisor must bring the array back to full redundancy with
        # ZERO operator involvement
        snap = _wait_healed(supervisor, svc.store)
        inc = snap["last_incident"]
        assert inc["shard"] == chaos_victim and inc["drained"] is True, snap
        assert inc["cause"] in ("probe", "error_burst", "observed_drained")
        print(f"chaos drill: auto-detected ({inc['cause']}), auto-drained, "
              f"auto-rebuilt in {inc.get('restore_s', 0):.2f}s — "
              f"no operator call")
        # graph now quiesced (mutator stopped): a second device kill must
        # leave a seeded answer bit-identical through degraded serving AND
        # through the auto-rebuild
        ref_req = dict(dfg=dfg, batch=list(range(8)),
                       weights_ref="deployed", seed=424242)
        ref = boot.call("run", **ref_req, timeout=600)["Result"]
        _kill_device(svc.store, chaos_victim)
        degraded = boot.call("run", **ref_req, timeout=600)["Result"]
        assert (np.asarray(ref) == np.asarray(degraded)).all(), \
            "degraded result diverged from healthy reference"
        _wait_healed(supervisor, svc.store)
        healed = boot.call("run", **ref_req, timeout=600)["Result"]
        assert (np.asarray(ref) == np.asarray(healed)).all(), \
            "post-auto-rebuild result diverged from healthy reference"
        st = boot.call("stats", timeout=600)
        assert st["health"]["incidents"] >= 2, st["health"]
        assert all(s == "healthy" for s in st["health"]["states"])
        assert st["replication"]["failed_shards"] == [], st
        print(f"chaos drill: {st['health']['incidents']} incidents healed, "
              f"reference answer bit-identical healthy/degraded/rebuilt")

    stats = boot.call("stats", timeout=600)
    if supervisor is not None:
        supervisor.stop()
    runtime.stop()

    qos = stats["qos"]
    for kind, xs in lat.items():
        if not xs:
            continue
        xs = np.array(xs) * 1e3
        print(f"{kind:12s} {len(xs):4d} reqs: p50={np.percentile(xs, 50):.1f} "
              f"ms p95={np.percentile(xs, 95):.1f} ms "
              f"p99={np.percentile(xs, 99):.1f} ms")
    print(f"scheduler: {qos['groups']} groups, "
          f"avg group size {qos['avg_group_size']:.1f}, "
          f"throughput {qos['throughput_rps']:.1f} req/s, "
          f"{qos['expired']} expired, {qos['rejected']} rejected")
    cache = stats.get("embcache", {})
    if cache:
        print(f"embcache: hit rate {cache['hit_rate']:.2f} "
              f"({cache['hits']} hits / {cache['misses']} misses, "
              f"{cache['invalidations']} invalidations)")
    print(f"store: {stats['store']['pages_h']} H-pages, "
          f"{stats['store']['pages_l']} L-pages, "
          f"{stats['store']['unit_updates']} unit updates, "
          f"{stats['device']['read_pages']} device page reads")
    for i, sh in enumerate(stats.get("shards", [])):
        hr = sh["embcache"]["hit_rate"] if sh["embcache"] else 0.0
        print(f"  shard {i}: {sh['device']['read_pages']} reads, "
              f"{sh['device']['written_pages']} writes, "
              f"cache hit rate {hr:.2f}")
    for link in qos.get("shard_links") or []:
        extra = (f", {link['channel_bytes'] / 1e6:.1f} MB over RoP"
                 if "channel_bytes" in link else " (in-process)")
        print(f"  link {link['shard']}: {link['calls']} commands{extra}")
    svc.close()
    # CI drills run with REPRO_LOCK_WITNESS=1: every lock the drill
    # touched was order-checked live; a recorded inversion fails here
    from repro.concurrency import assert_clean, witness_enabled, \
        witness_report
    if witness_enabled():
        rep = witness_report()
        print(f"lock witness: {len(rep['edges'])} nesting edges observed, "
              f"{len(rep['violations'])} violations")
        assert_clean()
    if errors:
        print(f"{len(errors)} failed requests; first: {errors[0]}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
