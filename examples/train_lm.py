"""Train the ~125M-param xlstm-125m for a few hundred steps at reduced
sequence length with checkpoint/restart (kill it mid-run and re-invoke: it
resumes from the last committed step and replays the same data stream).

  PYTHONPATH=src python examples/train_lm.py --steps 200
(full-size config; pass --smoke for a quick CPU sanity run)
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true")
    a = ap.parse_args()
    argv = ["--arch", "xlstm-125m", "--steps", str(a.steps),
            "--batch", "4", "--seq", "256", "--ckpt", "/tmp/xlstm_ckpt",
            "--ckpt-every", "20"]
    if a.smoke:
        argv.append("--smoke")
    train_main(argv)
