"""The paper's storage technique generalized: LM serving over a paged KV
cache (GraphStore VID->LPN = sequence->page chains) with continuous
batching.  ``--pallas`` routes attention through the Pallas
decode_attention kernel (scalar-prefetched page tables; interpret on CPU).

  PYTHONPATH=src python examples/serve_lm_paged.py --requests 8
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
