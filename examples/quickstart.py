"""Quickstart: the paper's headline flow in ~40 lines.

Build a graph -> bulk-ingest into GraphStore (near-storage) -> program the
Hetero accelerator -> run GCN inference through a DFG over RPC.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.service import HolisticGNNService, make_service_dfg
from repro.core import gnn
from repro.kernels.ops import program_config
from repro.rpc import RPCServer, RPCClient

rng = np.random.default_rng(0)

# 1. a power-law graph + node embeddings (the "raw data on storage")
n_vertices, n_edges, feat = 1000, 8000, 64
edges = np.stack([rng.integers(0, n_vertices, n_edges),
                  rng.zipf(1.4, n_edges) % n_vertices], 1).astype(np.int64)
embeddings = rng.standard_normal((n_vertices, feat)).astype(np.float32)

# 2. the CSSD-side service, reached over RPC-over-PCIe
service = HolisticGNNService(h_threshold=32, pad_to=32)
client = RPCClient(RPCServer(service))

stats = client.call("update_graph", edge_array=edges, embeddings=embeddings)
print(f"bulk ingest: total={stats['total_s']*1e3:.1f} ms, "
      f"user-visible={stats['user_visible_s']*1e3:.1f} ms "
      f"(graph preprocessing overlapped)")

# 3. program the User logic: vector (SpMM) + systolic (GEMM) accelerators
reconfig_s = program_config(service.xbuilder, "hetero")
print(f"XBuilder reconfigured to Hetero in {reconfig_s*1e3:.2f} ms")

# 4. ship a GCN as a dataflow graph; sampling runs where the data lives
params = gnn.init_params("gcn", [feat, 32, 16], seed=1)
dfg = make_service_dfg("gcn", num_layers=2, fanouts=[10, 10])
weights = {k: v for k, v in gnn.dfg_feeds("gcn", params, None, []).items()
           if k != "H"}
out = client.call("run", dfg=dfg.save(), batch=[1, 2, 3, 4],
                  weights=weights)
print(f"inferred embeddings for 4 targets: {out['Result'][:4].shape}")
print(f"executed on devices: {sorted({d for _, d in service.engine.trace})}")
print(f"RoP traffic: {client.tx.stats.bytes_moved/1e3:.1f} KB sent, "
      f"{client.rx.stats.bytes_moved/1e3:.1f} KB received")
