"""Mutable graph support (paper Fig. 20): replay a DBLP-like growth stream
of daily vertex/edge inserts and deletions against GraphStore and report
per-day latency and page statistics.

  PYTHONPATH=src python examples/mutable_graph.py [--days 23]
"""
import argparse
import time

import numpy as np

from repro.store.blockdev import BlockDevice
from repro.store.graphstore import GraphStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=23)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    gs = GraphStore(BlockDevice(), h_threshold=64)
    gs.update_graph(np.array([[0, 1], [1, 2]], np.int64))
    next_vid = 3
    per_day = []
    for day in range(args.days):
        t0 = time.perf_counter()
        for v in range(next_vid, next_vid + 36):
            gs.add_vertex(v)
        next_vid += 36
        for _ in range(880):
            gs.add_edge(int(rng.integers(0, next_vid)),
                        int(rng.integers(0, next_vid)))
        for _ in range(71):
            v = int(rng.integers(0, next_vid))
            nb = gs.get_neighbors(v)
            nb = nb[nb != v]
            if len(nb):
                gs.delete_edge(v, int(nb[0]))
        for _ in range(2):
            gs.delete_vertex(int(rng.integers(0, next_vid)))
        per_day.append(time.perf_counter() - t0)
    per_day = np.array(per_day) * 1e3
    print(f"{args.days} days, ~989 unit ops/day: "
          f"mean={per_day.mean():.0f} ms worst={per_day.max():.0f} ms")
    print(f"H-pages={gs.stats.pages_h} L-pages={gs.stats.pages_l} "
          f"L-splits={gs.stats.l_evictions} "
          f"written_pages={gs.dev.stats.written_pages}")


if __name__ == "__main__":
    main()
